#!/usr/bin/env python
"""Compare fresh benchmark rows against the committed baseline.

Usage::

    python scripts/bench_compare.py NEW.json BASELINE.json [--tolerance 8.0]
    python scripts/bench_compare.py NEW.json BASELINE.json --write-baseline

Fails (exit 1) when a row present in both files regressed by more than
``tolerance``× in ``us_per_call``, when the two files share no rows at
all (a renamed family would otherwise slip through silently), or when a
relative ordering check fails.  Rows present in the fresh run but absent
from the baseline are *warned about* (they are silently invisible to the
regression gate until recorded) — regenerate the baseline deliberately
with ``--write-baseline`` after adding bench rows.  Rows the baseline has
that the fresh run lacks are expected: CI smoke runs a size/family
subset.

The tolerance is deliberately loose: CI hosts and laptops differ wildly
in absolute disk/memory bandwidth, so this is a smoke check for
order-of-magnitude regressions (an accidentally-serialized pool, a cache
that stopped caching), not a microbenchmark gate.

Relative sanity checks ride along where the rows encode one — they hold
on any host because both sides run on the same hardware in the same
process:

* hot-tier rows must stay faster than the matching disk rows;
* the streaming reshard must stay faster than the VIA_UCP convert+load
  path it replaced;
* the delta save must stay faster than the full save of the same state
  (it writes a fraction of the bytes; if it isn't faster, the diff is
  writing shards it should have inherited);
* a 32-reader fan-out fleet must finish before 32 independent disk
  readers (if it doesn't, the peer store / serving hot set stopped
  deduplicating work).
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys


def load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        doc = json.load(f)
    return {
        r["name"]: float(r["us_per_call"])
        for r in doc["rows"]
        if r.get("us_per_call") is not None
    }


# (fast row, slow row): fast must beat slow whenever both were measured.
ORDERING_PAIRS = [
    (f"{fast}_{size}", f"{slow}_{size}")
    for size in ("small", "medium", "large")
    for fast, slow in (
        ("hot_capture", "disk_save"),
        ("hot_restore_direct", "disk_restore_direct"),
        ("hot_restore_reshard", "disk_restore_reshard"),
        ("hot_recover_failed", "disk_restore_reshard"),
        ("reshard_stream", "via_ucp_total"),
        ("reshard_stream_mixed", "via_ucp_total"),
        ("delta_save", "delta_full_save"),
        ("codec_delta_save", "codec_full_save"),
        ("fanout_readers_32", "fanout_independent_32"),
    )
]


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("new")
    p.add_argument("baseline")
    p.add_argument(
        "--tolerance", type=float, default=8.0,
        help="max allowed slowdown factor vs the baseline (default 8x)",
    )
    p.add_argument(
        "--write-baseline", action="store_true",
        help="overwrite BASELINE with NEW (deliberate regeneration after "
        "adding/renaming bench rows) instead of comparing",
    )
    p.add_argument(
        "--obs-rows", default="",
        help="comma-separated row names held to --obs-tolerance instead of "
        "--tolerance: the obs-disabled no-regression gate (a NEW run with "
        "tracing off must match the pre-instrumentation baseline closely "
        "on these rows, proving the disabled tracer is near-zero cost)",
    )
    p.add_argument(
        "--obs-tolerance", type=float, default=1.02,
        help="max slowdown factor for --obs-rows (default 1.02 = 2%%)",
    )
    args = p.parse_args()

    if args.write_baseline:
        rows = load_rows(args.new)  # validate BEFORE clobbering the baseline
        shutil.copyfile(args.new, args.baseline)
        print(f"bench-compare: wrote {len(rows)} rows from "
              f"{args.new} as the new baseline {args.baseline}")
        return 0

    new = load_rows(args.new)
    base = load_rows(args.baseline)
    failures: list[str] = []

    obs_rows = {s for s in args.obs_rows.split(",") if s}
    missing_obs = obs_rows - (set(new) & set(base))
    if missing_obs:
        failures.append(
            f"--obs-rows not present in both files: {sorted(missing_obs)} "
            "(an ungated obs row would pass vacuously)"
        )

    common = sorted(set(new) & set(base))
    if not common:
        failures.append(
            f"no comparable rows between {args.new} ({sorted(new)[:5]}...) "
            f"and {args.baseline}"
        )
    for name in common:
        tol = args.obs_tolerance if name in obs_rows else args.tolerance
        ratio = new[name] / base[name] if base[name] else float("inf")
        status = "OK" if name not in obs_rows else "OK (obs-gated)"
        if ratio > tol:
            status = f"REGRESSED >{tol}x"
            failures.append(f"{name}: {ratio:.2f}x slower than baseline "
                            f"(limit {tol}x)")
        print(f"{name}: {new[name]:.0f}us vs baseline {base[name]:.0f}us "
              f"({ratio:.2f}x) {status}")

    # Rows the baseline has never seen: not a failure (CI smoke runs a
    # subset), but never silent — an unrecorded row is an ungated row.
    only_new = sorted(set(new) - set(base))
    for name in only_new:
        print(
            f"WARNING: {name} ({new[name]:.0f}us) not in baseline "
            f"{args.baseline} — unrecorded rows are not regression-gated; "
            "rerun with --write-baseline to record it",
            file=sys.stderr,
        )

    for fast, slow in ORDERING_PAIRS:
        if fast in new and slow in new and new[fast] >= new[slow]:
            failures.append(
                f"{fast} ({new[fast]:.0f}us) not faster than {slow} "
                f"({new[slow]:.0f}us)"
            )

    if failures:
        print("\nbench-compare FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"bench-compare: {len(common)} rows within {args.tolerance}x of "
          f"baseline, {len(only_new)} new-row warnings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
