#!/usr/bin/env python
"""Compare fresh benchmark rows against the committed baseline.

Usage::

    python scripts/bench_compare.py NEW.json BASELINE.json [--tolerance 8.0]

Fails (exit 1) when a row present in both files regressed by more than
``tolerance``× in ``us_per_call``, or when the fresh run is missing a row
family the baseline has.  The tolerance is deliberately loose: CI hosts
and laptops differ wildly in absolute disk/memory bandwidth, so this is a
smoke check for order-of-magnitude regressions (an accidentally-serialized
pool, a cache that stopped caching), not a microbenchmark gate.

Relative sanity checks ride along where the rows encode one — hot-tier
rows must stay faster than the matching disk rows at the same size, which
holds on any host because both run on the same hardware in the same
process.
"""

from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        doc = json.load(f)
    return {
        r["name"]: float(r["us_per_call"])
        for r in doc["rows"]
        if r.get("us_per_call") is not None
    }


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("new")
    p.add_argument("baseline")
    p.add_argument(
        "--tolerance", type=float, default=8.0,
        help="max allowed slowdown factor vs the baseline (default 8x)",
    )
    args = p.parse_args()

    new = load_rows(args.new)
    base = load_rows(args.baseline)
    failures: list[str] = []

    common = sorted(set(new) & set(base))
    if not common:
        failures.append(
            f"no comparable rows between {args.new} ({sorted(new)[:5]}...) "
            f"and {args.baseline}"
        )
    for name in common:
        ratio = new[name] / base[name] if base[name] else float("inf")
        status = "OK"
        if ratio > args.tolerance:
            status = f"REGRESSED >{args.tolerance}x"
            failures.append(f"{name}: {ratio:.2f}x slower than baseline")
        print(f"{name}: {new[name]:.0f}us vs baseline {base[name]:.0f}us "
              f"({ratio:.2f}x) {status}")

    # hot-vs-disk ordering: same-host, same-process — must hold anywhere.
    for size in ("small", "medium", "large"):
        pairs = [
            (f"hot_capture_{size}", f"disk_save_{size}"),
            (f"hot_restore_direct_{size}", f"disk_restore_direct_{size}"),
            (f"hot_restore_reshard_{size}", f"disk_restore_reshard_{size}"),
            (f"hot_recover_failed_{size}", f"disk_restore_reshard_{size}"),
        ]
        for hot, disk in pairs:
            if hot in new and disk in new and new[hot] >= new[disk]:
                failures.append(
                    f"{hot} ({new[hot]:.0f}us) not faster than {disk} "
                    f"({new[disk]:.0f}us)"
                )

    if failures:
        print("\nbench-compare FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"bench-compare: {len(common)} rows within {args.tolerance}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
