#!/usr/bin/env bash
# Fast-fail CI for the repo (run by .github/workflows/ci.yml).
#
# Stage 1 — import smoke: import every module under src/repro.  A missing
# module (the failure mode that once broke the whole suite at collection)
# fails here in seconds instead of deep inside pytest.
# Stage 2 — the test suite.  The full suite exceeds 2 minutes, so the
# default lane for iteration is `--fast`: it deselects tests marked `slow`
# (multi-second subprocess/e2e/property tests).  The tier-1 gate
# (ROADMAP.md) remains the FULL suite — run ci.sh without --fast before
# shipping (the main/nightly CI lane does).
# Stage 3 — benchmark smoke: a small-size save-cost + hot-tier run with
# --json, compared against the committed BENCH_checkpointing.json baseline
# within a loose tolerance (scripts/bench_compare.py) so an
# order-of-magnitude perf regression or a broken recording fails in CI
# rather than on the next real benchmark run.
#
# Stage 4 — obs smoke: run elastic-resume phase 1 with --trace and
# validate the exported Chrome trace-event file (schema, event/containment
# invariants, non-trivial span count) via repro.obs.validate_chrome_trace,
# so a broken exporter or an instrumentation path that stops emitting
# fails the PR lane, not the next person opening Perfetto.
#
# Stage 5 — chaos smoke (opt-in, --chaos-smoke): three fixed seeds through
# the deterministic fault-injection harness (scripts/chaos_sweep.py), so a
# regression in the recovery ladder fails the PR lane in seconds; the
# nightly lane runs the full bounded sweep separately.
#
# Stage 0 — lint (opt-in, --lint): the project-invariant static analyzer
# (repro.analysis — lock/clock/decode/catalog/except discipline plus the
# PR 5/7 regression pins, DESIGN.md §11).  Pure stdlib, imports no model
# code, runs in under a second — so it goes first and a broken invariant
# fails before anything heavyweight starts.
#
# Usage: scripts/ci.sh [--fast] [--lint] [--chaos-smoke] [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

stage="setup"
smoke_json=""
smoke_trace=""
cleanup() {
    if [[ -n "$smoke_json" ]]; then rm -f "$smoke_json"; fi
    if [[ -n "$smoke_trace" ]]; then rm -f "$smoke_trace"; fi
}
on_err() { echo "ci.sh: FAILED during stage: $stage" >&2; }
trap cleanup EXIT
trap on_err ERR

PYTEST_ARGS=()
chaos_smoke=0
lint=0
while [[ $# -gt 0 ]]; do
    case "$1" in
        --fast) PYTEST_ARGS+=(-m "not slow"); shift ;;
        --chaos-smoke) chaos_smoke=1; shift ;;
        --lint) lint=1; shift ;;
        *) break ;;
    esac
done

stage="tracked-bytecode-guard"
# Committed .pyc files churn on every run and bloat diffs; they were purged
# once (git rm -r --cached) and must never come back.
if git ls-files | grep -E '(^|/)__pycache__/|\.py[cod]$' >/dev/null; then
    echo "ci.sh: tracked __pycache__/.pyc entries found:" >&2
    git ls-files | grep -E '(^|/)__pycache__/|\.py[cod]$' >&2
    exit 1
fi

if [[ "$lint" == 1 ]]; then
    stage="lint"
    python -m repro.analysis src/repro
fi

stage="import-smoke"
python - <<'PY'
import importlib
import pkgutil
import sys

import repro

mods = ["repro"]
for m in pkgutil.walk_packages(repro.__path__, prefix="repro."):
    mods.append(m.name)

# Subsystem packages the walk must have discovered — a packaging mistake
# (missing __init__.py, renamed dir) would otherwise shrink the walk
# silently and the smoke would "pass" while covering less.
for required in ("repro.core", "repro.ckpt", "repro.hot", "repro.serve"):
    assert required in mods, f"import-smoke: {required} not discovered"

failed = []
for name in sorted(mods):
    try:
        importlib.import_module(name)
    except Exception as e:  # noqa: BLE001 - report every import failure
        failed.append(name)
        print(f"IMPORT FAIL {name}: {type(e).__name__}: {e}")
print(f"import-smoke: {len(mods) - len(failed)}/{len(mods)} modules importable")
if failed:
    sys.exit(1)
PY

stage="pytest"
python -m pytest -x -q "${PYTEST_ARGS[@]}" "$@"

stage="bench-smoke"
smoke_json="$(mktemp /tmp/bench_smoke.XXXXXX.json)"
python -m benchmarks.run --only save_cost,hot_tier,delta,codec,fanout \
    --sizes small --json "$smoke_json" >/dev/null
python - "$smoke_json" <<'PY'
import json
import sys

doc = json.load(open(sys.argv[1]))
rows = doc["rows"]
assert rows, "benchmark smoke produced no rows"
assert all(r["derived"] != "ERROR" for r in rows), f"benchmark smoke errored: {rows}"
names = {r["name"] for r in rows}
assert any(n.startswith("save_parallel_") for n in names), names
assert any(n.startswith("hot_capture_") for n in names), names
assert any(n.startswith("delta_save_") for n in names), names
assert any(n.startswith("chain_restore_") for n in names), names
assert any(n.startswith("codec_full_save_") for n in names), names
assert any(n.startswith("codec_delta_save_") for n in names), names
assert any(n.startswith("codec_restore_") for n in names), names
assert any(n.startswith("fanout_readers_") for n in names), names
print(f"bench-smoke: {len(rows)} rows ok")
PY

stage="bench-compare"
python scripts/bench_compare.py "$smoke_json" BENCH_checkpointing.json

stage="obs-smoke"
smoke_trace="$(mktemp /tmp/obs_smoke.XXXXXX.json)"
python examples/elastic_resume.py --phase 1 --trace "$smoke_trace" >/dev/null
python - "$smoke_trace" <<'PY'
import json
import sys

from repro.obs import validate_chrome_trace

doc = json.load(open(sys.argv[1]))
assert doc.get("otherData", {}).get("schema") == "repro-trace/v1", doc.get("otherData")
n = validate_chrome_trace(doc)
# phase 1 does 10 train steps and 2 sync saves; a healthy trace has far
# more than a handful of events — a near-empty one means instrumentation
# silently stopped emitting.
assert n >= 50, f"obs-smoke: only {n} trace events (instrumentation broken?)"
names = {e["name"] for e in doc["traceEvents"] if e.get("ph") == "X"}
for required in ("train.step", "ckpt.save", "save.fsync", "ckpt.commit"):
    assert required in names, f"obs-smoke: no {required} spans in {sorted(names)}"
print(f"obs-smoke: {n} trace events ok")
PY

if [[ "$chaos_smoke" == 1 ]]; then
    stage="chaos-smoke"
    python scripts/chaos_sweep.py --seed-list 0,1,2 --events 8
fi

stage="done"
