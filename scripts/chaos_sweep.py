#!/usr/bin/env python
"""Chaos-sweep CLI: replay seeded fault schedules against the recovery
ladder (see DESIGN.md §8 and repro.chaos).

Fast smoke (CI PR lane):        chaos_sweep.py --seed-list 0,1,2 --events 8
Nightly bounded sweep:          chaos_sweep.py --seeds 25 --shrink --artifact chaos-failures.json
Replay one fallen seed locally: chaos_sweep.py --seed 17 --events 12 --shrink

Exit code 1 when any seed violates the ladder invariant; with --shrink
each failure is reduced to its minimal fault prefix and printed as a
ready-to-paste regression test.  --artifact writes the failing schedules
as JSON (what the nightly lane uploads).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.chaos.sweep import (  # noqa: E402
    emit_regression_test,
    failing_artifact,
    run_seed,
    shrink,
    SweepResult,
)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--seeds", type=int, default=None,
                   help="sweep seeds 0..N-1")
    g.add_argument("--seed-list", type=str, default=None,
                   help="comma-separated explicit seeds")
    g.add_argument("--seed", type=int, default=None,
                   help="one seed")
    ap.add_argument("--events", type=int, default=12,
                    help="train/save events per seed (default 12)")
    ap.add_argument("--shrink", action="store_true",
                    help="shrink failing schedules to their minimal prefix "
                         "and print regression tests")
    ap.add_argument("--artifact", type=Path, default=None,
                    help="write failing schedules as JSON to this path")
    args = ap.parse_args()

    if args.seed is not None:
        seeds = [args.seed]
    elif args.seed_list is not None:
        seeds = [int(s) for s in args.seed_list.split(",") if s.strip()]
    else:
        seeds = list(range(args.seeds if args.seeds is not None else 25))

    reports = []
    t0 = time.time()
    for seed in seeds:
        rep = run_seed(seed, events=args.events)
        status = "ok" if rep.ok else "FAIL"
        faults = next(
            (line for line in rep.log if line.startswith("fired:")), "fired: none"
        )
        print(f"  seed {seed:>4}: {status:4} "
              f"({rep.events_completed}/{args.events} events; "
              f"{faults.split(chr(10))[0][7:80]})")
        reports.append(rep)
    result = SweepResult(reports)
    print(f"{result.describe()}  [{time.time() - t0:.1f}s]")

    shrunk: dict[int, object] = {}
    if args.shrink:
        for rep in result.failed:
            small = shrink(rep, events=args.events)
            shrunk[rep.seed] = small
            print(f"\nseed {rep.seed} shrunk to {len(small.schedule)} fault(s); "
                  "regression test:\n")
            print(emit_regression_test(small, events=args.events))

    # Written after shrinking so each failure's artifact entry carries the
    # minimal failing prefix and ITS obs timeline (the replay that the
    # regression test pins), not the original long run's.
    if args.artifact is not None and result.failed:
        args.artifact.write_text(
            json.dumps(failing_artifact(result, shrunk=shrunk), indent=1)
        )
        print(f"failing schedules written to {args.artifact}")

    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
