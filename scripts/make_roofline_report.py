"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from results/dryrun.jsonl."""

import json
import sys
from collections import defaultdict

PEAK = 197e12
HBM = 819e9
LINK = 50e9


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x*1e3:.1f}m"
    return f"{x*1e6:.0f}µ"


def load(path):
    best = {}
    for line in open(path):
        line = line.strip()
        if not line:
            continue
        r = json.loads(line)
        key = (r["arch"], r["shape"], r["mesh"], r.get("tag", "baseline"))
        best[key] = r  # last wins
    return best


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl"
    tag = sys.argv[2] if len(sys.argv) > 2 else "baseline"
    recs = load(path)
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    archs = sorted({k[0] for k in recs})

    print("### Dry-run status (lower+compile per cell)\n")
    print("| arch | " + " | ".join(f"{s} 1pod / 2pod" for s in shapes) + " |")
    print("|---|" + "---|" * len(shapes))
    for a in archs:
        row = [a]
        for s in shapes:
            cell = []
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((a, s, mesh, tag))
                if r is None:
                    cell.append("…")
                elif r.get("skipped"):
                    cell.append("skip")
                elif r.get("ok"):
                    cell.append(f"OK({r.get('compile_s', '?')}s)")
                else:
                    cell.append("FAIL")
            row.append(" / ".join(cell))
        print("| " + " | ".join(row) + " |")

    print("\n### Roofline (single-pod 16×16; seconds per step at v5e specs)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "MODEL/HLO | roofline frac | temp GB/chip |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            r = recs.get((a, s, "16x16", tag))
            if not r or r.get("skipped") or not r.get("ok"):
                continue
            t = r["roofline"]
            mem = r.get("memory") or {}
            print(
                f"| {a} | {s} | {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} | "
                f"{fmt_s(t['collective_s'])} | {r['dominant'].replace('_s','')} | "
                f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']*100:.2f}% | "
                f"{(mem.get('temp_bytes_per_device') or 0)/1e9:.1f} |"
            )

    # failures
    fails = [(k, r) for k, r in recs.items() if not r.get("ok")]
    if fails:
        print("\n### Failures\n")
        for k, r in fails:
            print(f"- {k}: {r.get('error', '?')[:300]}")


if __name__ == "__main__":
    main()
